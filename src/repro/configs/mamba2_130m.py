"""mamba2-130m [ssm] — 24L d768 (attention-free) vocab=50280, ssm_state=128.

arXiv:2405.21060 — SSD (state-space duality).  No softmax attention at all:
the paper's streaming-MHA/LUT-softmax parts are inapplicable (DESIGN.md
§Arch-applicability); quantized projections + staged RMSNorm apply.
O(1)-state decode -> runs the ``long_500k`` cell.
"""

import dataclasses

from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=1,  # unused (attention-free)
        n_kv_heads=1,
        d_ff=0,
        vocab_size=50280,
        attn_kind="none",
        norm_kind="rmsnorm",
        act="silu",
        gated_mlp=False,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=64),
        tie_embeddings=True,
        serve_policy="int8_serve",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(),
        name="mamba2-130m-reduced",
        n_layers=2,
        d_model=32,
        vocab_size=128,
        ssm=SSMConfig(state_dim=16, head_dim=8, expand=2, chunk_size=16),
    )

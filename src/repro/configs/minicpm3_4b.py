"""minicpm3-4b [dense, MLA] — 62L d2560 40H (kv=40) d_ff=6400 vocab=73448.

MLA (multi-head latent attention) per hf:openbmb/MiniCPM3-4B:
q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head=64.
MiniCPM muP-style scaling: scale_emb=12, scale_depth=1.4, dim_model_base=256.
"""

import dataclasses

from repro.configs.base import MLAConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_ff=6400,
        vocab_size=73448,
        head_dim=64,
        attn_kind="mla",
        mla=MLAConfig(
            q_lora_rank=768,
            kv_lora_rank=256,
            qk_nope_head_dim=64,
            qk_rope_head_dim=32,
            v_head_dim=64,
        ),
        norm_kind="rmsnorm",
        act="silu",
        gated_mlp=True,
        rope_theta=10000.0,
        tie_embeddings=True,
        emb_scale=12.0,
        residual_scale=1.4 / (62 ** 0.5),
        logit_scale=256.0 / 2560.0,
        serve_policy="int8_serve",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(),
        name="minicpm3-4b-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=128,
        head_dim=16,
        mla=MLAConfig(
            q_lora_rank=32,
            kv_lora_rank=16,
            qk_nope_head_dim=8,
            qk_rope_head_dim=8,
            v_head_dim=8,
        ),
        residual_scale=1.4 / (2 ** 0.5),
        logit_scale=1.0,
        emb_scale=1.0,
    )

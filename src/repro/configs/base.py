"""Config dataclasses for the architecture zoo and the framework."""

from __future__ import annotations

import dataclasses
from typing import Literal

from repro.core.precision import PrecisionPolicy
from repro.core.quant import QuantConfig


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2 style; minicpm3)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD (arXiv:2405.21060)."""

    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 64
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: Mamba2 backbone + shared attention block."""

    attn_every: int = 6  # shared attn applied at layer_idx % attn_every == 0
    concat_residual: bool = True  # shared block sees concat(x, x_embed)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # default d_model // n_heads
    attn_kind: Literal["gqa", "mla", "none"] = "gqa"
    norm_kind: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["silu", "gelu"] = "silu"
    gated_mlp: bool = True
    rope_theta: float = 10000.0
    sliding_window: int | None = None
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid: HybridConfig | None = None
    frontend: Literal["patch", "audio"] | None = None
    frontend_dim: int = 0  # stub modality embedding dim (0 = d_model)
    n_frontend_tokens: int = 0
    is_encoder: bool = False
    tie_embeddings: bool = False
    attn_bias: bool = False
    mlp_bias: bool = False
    use_rope: bool = True  # physics models use learned positions instead
    # muP-style scaling (MiniCPM): scale_emb, scale_depth, dim_model_base
    emb_scale: float = 1.0
    residual_scale: float = 1.0  # applied to each residual branch
    logit_scale: float = 1.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # legacy per-model quantization knobs; lowered onto `precision` when set
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    # declarative per-layer precision: a PrecisionPolicy or preset name
    # (core/precision.py); None = fall back to the legacy `quant` shim
    precision: PrecisionPolicy | str | None = None
    # recommended serving preset for this arch (`--policy auto` in the
    # launchers resolves to this)
    serve_policy: str = "float"
    # paper-style extras (physics models)
    input_vec_size: int = 0  # continuous-input models (paper's three)
    seq_len: int = 0  # fixed seq for physics models
    n_classes: int = 0
    pool: Literal["mean", "last", "none"] = "none"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to a multiple of 256 for clean TP sharding."""
        return ((self.vocab_size + 255) // 256) * 256

    def param_count_estimate(self) -> int:
        """Rough 6ND-style N (for MODEL_FLOPS; exact count via params.py)."""
        d, l = self.d_model, self.n_layers
        emb = self.padded_vocab_size * d
        if self.attn_kind == "mla" and self.mla is not None:
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * qk
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank
                * self.n_heads
                * (m.qk_nope_head_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        elif self.attn_kind == "none":
            attn = 0
        else:
            hd = self.resolved_head_dim
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.family == "hybrid" and self.ssm is not None:
            # Mamba2 backbone layers + one weight-shared attention block
            s = self.ssm
            di = s.d_inner(d)
            per_mamba = (
                d * (2 * di + 2 * s.n_groups * s.state_dim + s.n_heads(d))
                + di * d
            )
            w = 2 * d  # shared block works in concat(x, x_embed) width
            ff_mult = 3 if self.gated_mlp else 2
            shared = 4 * w * w + ff_mult * w * self.d_ff + w * d
            return emb + l * per_mamba + shared + (
                0 if self.tie_embeddings else emb
            )
        if self.moe is not None:
            ff_mult = 3 if self.gated_mlp else 2
            ffn = self.moe.n_experts * ff_mult * d * self.moe.d_expert
        elif self.ssm is not None and self.attn_kind == "none":
            s = self.ssm
            di = s.d_inner(d)
            ffn = d * (2 * di + 2 * s.n_groups * s.state_dim + s.n_heads(d)) + di * d
        else:
            ff_mult = 3 if self.gated_mlp else 2
            ffn = ff_mult * d * self.d_ff
        return emb + l * (attn + ffn) + (0 if self.tie_embeddings else emb)

    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count_estimate()
        dense_like = dataclasses.replace(self, moe=None, d_ff=0, gated_mlp=False)
        base = dense_like.param_count_estimate()
        ff_mult = 3 if self.gated_mlp else 2
        active_ffn = (
            self.n_layers * self.moe.top_k * ff_mult * self.d_model * self.moe.d_expert
        )
        return base + active_ffn


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelismConfig:
    """Logical-axis -> mesh-axes mapping knobs (see distributed/sharding)."""

    dp: bool = True  # batch over ('pod','data')
    fsdp: bool = True  # weight non-TP axis over 'data'
    tp: bool = True  # heads/mlp/vocab over 'model'
    ep: bool = True  # experts over 'model'
    sp: bool = False  # sequence over 'model' (long-context cells)
    remat: Literal["none", "minimal", "full"] = "minimal"
    grad_accum: int = 1  # microbatch accumulation (activation memory / k)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: Literal["cosine", "wsd", "linear"] = "cosine"
    decay_fraction: float = 0.1  # WSD decay phase fraction
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    checkpoint_every: int = 200
    keep_checkpoints: int = 3
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq_len: int = 1024
    # Engine-default softmax temperature (0.0 = greedy).  A per-request
    # SamplingParams.temperature overrides it; the knobs ride the
    # compiled programs as traced per-slot arrays (serve/sampling.py),
    # so mixing greedy and sampled requests in one batch mints no extra
    # programs — the len(prefill_buckets)+2 jit budget is unchanged and
    # test-enforced.
    temperature: float = 0.0
    # Declarative serving precision: a PrecisionPolicy, a preset name
    # ("int8_serve", "paper_vu13p", "qat_fixed<12,6>", ...), or None.
    # (The legacy int8_weights/int8_kv_cache/lut_softmax booleans were
    # removed after their deprecation cycle; see README "Precision
    # policies" for the migration table.)
    policy: PrecisionPolicy | str | None = None
    # --- KV-cache layout (serve/kv_cache.py CacheManager) ---
    # "dense": per-slot slabs of max_seq_len tokens (the historical
    # layout).  "paged": block-table-indexed pages — long contexts
    # allocate on demand, freed slots return pages immediately.  Families
    # whose caches are not position-addressed (SSM/hybrid, rolling
    # sliding-window) fall back to dense automatically.
    kv_layout: Literal["dense", "paged"] = "dense"
    # Tokens per page (paged layout); must divide max_seq_len so every
    # slot's logical view is a whole number of fixed-stride pages.
    kv_page_size: int = 16
    # Physical pages in the pool (paged layout).  None = enough for every
    # slot at full length plus the reserved trash page (no oversubscription);
    # set lower to oversubscribe memory for long-max_seq_len workloads.
    kv_pages: int | None = None
    # Prefix-cache page sharing (paged layout only).  Full prompt pages are
    # hash-chained into a prefix index; a same-prefix admission maps its
    # leading block-table entries to the already-filled pages (refcounted)
    # instead of allocating and filling fresh ones.  Finished requests'
    # registered pages are retained (refcount 0, evictable LRU) so repeated
    # prompts keep hitting after their first tenant completes.  Decode
    # writes into a shared page copy-on-write a private copy first, so
    # every logit stays bit-identical to the dense layout — greedy
    # (temperature=0) token streams are bit-identical too,
    # test-enforced.  Unseeded sampled (temperature>0) streams are
    # equally distributed but not reproducible against a dense run:
    # skipping a prefill dispatch reshuffles which engine PRNG key
    # samples which token.  Requests with an explicit
    # SamplingParams.seed are exempt — their streams are keyed by
    # (seed, position) and survive any rescheduling (test-enforced).
    # A hit additionally skips the prompt-prefill dispatch (prefill-skip):
    # bit-exact float-GQA engines teacher-force the uncovered tail through
    # the decode program, every other datapath (MLA, int8 KV, LUT softmax)
    # replays it through the cache-extending prefill program (see
    # ``cache_extend`` and the README datapath-capability matrix).
    # No-op for the dense layout.
    kv_prefix_cache: bool = False
    # Page-aware preemption (paged layout only).  When the page pool cannot
    # cover the queue head's reservation, preempt the youngest resident
    # request — free its private pages and re-queue it at the queue front
    # with prompt + generated-so-far as a resumable prompt — instead of
    # head-of-line blocking until pages drain.  A resume replays the prompt
    # part through prefill math (whole-prompt dispatch on bit-exact float
    # GQA; the cache-extending prefill program elsewhere) and the
    # generated part through the teacher-forced decode scan — the same
    # math that originally wrote each position — so greedy token streams
    # stay identical to the unpreempted run on every datapath (see the
    # README datapath-capability matrix).  The identity guarantee is on
    # logits and greedy token streams; a resume changes the PRNG
    # dispatch schedule for unseeded sampled decoding (seeded requests
    # are position-keyed and reproduce exactly, test-enforced).
    kv_preemption: bool = False
    # --- tiered KV cache: host-memory victim tier (paged + prefix cache) ---
    # Host-memory pages backing the prefix cache.  When > 0 (and
    # ``kv_victim_tier`` is on), a registered page evicted off the device
    # LRU under pool pressure spills its pool rows (k/v, int8 scale, MLA
    # latent pools alike) into a pinned host-side numpy ring of this many
    # pages instead of being discarded, keeping its prefix-index chain
    # key alive.  A later same-prefix admission that walks past device
    # coverage into the host tier swaps the spilled rows back into fresh
    # device pages — one batched host->device copy applied at the next
    # dispatch (``CacheManager.flush_swaps``, next to the CoW flush) —
    # and admits as a normal prefix hit with prefill-skip, so a warm
    # prefix larger than the device pool costs a page copy instead of a
    # recompute.  Spilled pages survive their tenant's finish (and the
    # device eviction) but not a process restart.  0 = no victim tier
    # (evictions discard, the pre-tier behavior).  Requires the paged
    # layout with kv_prefix_cache; silently inert otherwise.
    kv_host_pages: int = 0
    # Kill switch for the victim tier: with False, kv_host_pages is
    # ignored and evictions discard pages exactly as before.  Split from
    # kv_host_pages so deployments can size the ring in config and flip
    # the tier off operationally.
    kv_victim_tier: bool = True
    # --- engine v2: bucketed prefill + scan decode ---
    # Prompt-length buckets for prefill padding.  None = auto powers of two
    # up to max_seq_len; () = exact-length prefill (the v1 behavior, one
    # compiled program per distinct prompt length).
    prefill_buckets: tuple[int, ...] | None = None
    # Decode tokens generated per host dispatch (lax.scan over the fused
    # decode program).  1 = the v1 one-token-per-step path.
    decode_steps: int = 4
    # Max prompts admitted (prefilled) per engine step; 0 = fill every
    # free slot (v1 behavior).
    max_prefill_per_step: int = 0
    # --- chunked prefill (scheduler policy; serve/scheduler.py) ---
    # When set, a prompt longer than this admits by prefilling only its
    # first `prefill_chunk` tokens through the bucketed prefill program
    # and replaying the remaining prompt tail incrementally — teacher-
    # forced through the decode scan on bit-exact float-GQA engines,
    # chunk-at-a-time through the cache-extending prefill program on
    # every other datapath (MLA, int8 KV, LUT softmax; see
    # ``cache_extend``) — interleaved with resident decode steps, so
    # admitting a long prompt stalls resident decoding by at most a
    # chunk-sized dispatch instead of a full-prompt-sized one.  Greedy
    # token streams stay identical to unchunked on every datapath
    # (test-enforced; README datapath-capability matrix).  Must not
    # exceed the largest prefill bucket (the chunk dispatch reuses a
    # bucketed program), and requires a bucketable (position-addressed)
    # cache: setting it on SSM/hybrid or rolling sliding-window engines
    # is a configuration error.  None = off.
    prefill_chunk: int | None = None
    # Cache-extending prefill program (serve/executor.py).  One extra
    # jitted program — fixed shape (max_batch, window) — that runs the
    # prefill-path forward over a token window against the already-
    # populated caches, scattering new K/V through the dense/paged
    # write machinery.  Replayed tokens go through the same math that
    # produced the cache, which is what lets chunked prefill,
    # prefix-skip, and preemption-resume activate on datapaths whose
    # decode scan is NOT bit-exact with prefill (MLA latent caches,
    # int8 KV, LUT softmax).  Costs one compiled program on those
    # engines (len(prefill_buckets) + 2 total, CI-enforced); engines on
    # the Pallas kernel or without a bucketable cache fall back to the
    # legacy bit-exact gating.  Disable to restore the pre-extend
    # behavior (quantized datapaths silently skip the optimizations).
    cache_extend: bool = True
    # --- speculative decoding (serve/executor.py DraftWorker) ---
    # A small draft model greedily proposes up to ``spec_tokens`` tokens
    # per sampling-ready resident slot; the target model verifies the
    # whole proposal in ONE cache-extending prefill dispatch
    # (accept-prefix + one correction token).  Rejected drafts rewind
    # through the existing window-write machinery: extend writes are
    # position-idempotent, so the stale tail is simply overwritten by
    # the next accepted window.  Requires the cache-extending prefill
    # program (``cache_extend``); silently off (with a warning) where
    # that program is unavailable.  The target's jit budget is unchanged
    # — the draft model adds its own bounded program set (at most
    # len(prefill_buckets) draft prefills + 1 propose scan,
    # CI-enforced).  Greedy (temperature=0) token streams are bitwise
    # identical to non-speculative decoding on bit-exact datapaths
    # (test-enforced); per-request acceptance counters land in
    # telemetry.
    speculative: bool = False
    # Draft tokens proposed per verification window; clamped to the
    # extend program's window width.
    spec_tokens: int = 4
    # Zoo config name for the draft model (resolved by the Engine, which
    # initializes fresh params for it — pass explicit draft params via
    # ``Engine(draft=...)`` for a trained draft).  None/"self" = use the
    # target model as its own draft: acceptance approaches 1.0, which
    # exercises the full verify/rewind machinery and bounds the
    # best-case speedup, without needing a second trained model.
    draft_config: str | None = None
    # --- SLO-aware scheduling (serve/slo.py DeadlineScheduler) ---
    # Scheduling policy the engine builds when no explicit
    # ``scheduler_factory`` is passed.  "fifo": the historical
    # FifoScheduler.  "edf": earliest-deadline-first — the queue is
    # kept sorted by each request's absolute deadline (deadline-less
    # requests run FIFO behind every deadlined one), preemption picks
    # the least-urgent resident, and ``overdue_policy`` decides what
    # happens to a request whose deadline passes while it is still
    # queued.  True to the paper's hard-real-time physics-trigger
    # context, where past-deadline work is worthless.
    scheduler: Literal["fifo", "edf"] = "fifo"
    # Default per-request deadline in milliseconds, measured from
    # submit time; a request submitted without an explicit
    # ``deadline_s`` inherits it.  None = requests carry no deadline
    # unless they ask for one.
    deadline_ms: float | None = None
    # What the EDF scheduler does with a *queued* request whose deadline
    # already passed: "drop" removes it (the client streams a terminal
    # event with finish_reason="deadline" and its capacity is spent on
    # feasible work), "demote" moves it behind every still-feasible
    # request, "ignore" leaves pure EDF order.  Residents past deadline
    # always run to completion (counted as misses, never corrupted).
    overdue_policy: Literal["drop", "demote", "ignore"] = "drop"
    # --- step-phase tracing (serve/phases.py PhaseTracer) ---
    # Break each engine step into schedule / host_prep / dispatch /
    # device / sample timings (device time isolated by fencing every
    # dispatch with block_until_ready).  Off by default: the fenced
    # path serializes host and device work, so production throughput
    # measurements must opt in deliberately.  Per-step records land in
    # a ring buffer; p50/p95/p99 summaries under
    # ``Engine.telemetry["phases"]``.
    trace_phases: bool = False
    # Per-step records retained by the tracer's ring buffer.
    phase_ring: int = 512
    # Tracer flavor (serve/phases.py).  "fenced": the PR-7 tracer —
    # block_until_ready after every dispatch isolates device time
    # exactly, at the cost of serializing host and device (it measures a
    # pipeline it also destroys).  "overlap": never fences; instead it
    # reports ``device_overlap_s`` (the host-side span between a decode
    # dispatch returning and its collect starting — device time hidden
    # under host work), ``host_bubble_s`` (the residual blocking wait in
    # collect — host time NOT hidden), and ``overlap_efficiency`` =
    # overlap / (overlap + bubble).  The only mode that can measure the
    # async loop without un-pipelining it.
    phase_mode: Literal["fenced", "overlap"] = "fenced"
    # --- pipelined async engine loop (serve/api.py) ---
    # Double-buffered engine loop: while step N's decode dispatch is in
    # flight on device, the scheduler computes step N+1's decision and
    # the host preps its inputs, so schedule/host_prep/sample hide under
    # device time.  The executor splits into a non-blocking
    # ``dispatch(decision) -> InflightStep`` and a blocking
    # ``collect(inflight) -> StepOutput``; the device->host transfer of
    # sampled tokens is deferred one step, and the sampled-token carry
    # for step N+1's decode scan stays on device (no host round-trip
    # between consecutive decode dispatches).  Greedy (temperature=0)
    # token streams are bit-identical to the synchronous loop on every
    # datapath/layout (test-enforced); unseeded sampled streams are
    # equally distributed but may diverge (the dispatch schedule
    # reshuffles PRNG key splits, same caveat as prefix-skip and
    # preemption; seeded requests are position-keyed and reproduce
    # exactly).  Cancels
    # and EDF deadline drops act at a one-step-stale boundary: up to one
    # in-flight dispatch's tokens for a cancelled request are discarded,
    # and preemption victims are only picked among fully-collected slots
    # (see README "Async engine loop & mesh sharding").  Off by default:
    # the legacy loop runs byte-identical code.
    async_loop: bool = False
    # --- mesh-sharded decode (distributed/sharding.py) ---
    # Place params and KV caches with NamedSharding over a host mesh
    # (data x model, launch/mesh.make_host_mesh) via ShardingRules /
    # cache_shardings, so every prefill/extend/decode program compiles
    # against sharding-annotated operands.  On a 1-device host this is
    # the identity placement (token streams bit-identical, jit budget
    # unchanged — both test-enforced); on a multi-device host the paged
    # KV pools shard over kv_heads (TP) with the page table over batch.
    shard_decode: bool = False
    # Data-parallel replica fan-out (serve/router.py ReplicaRouter):
    # N independent engines behind one queue with least-loaded
    # admission.  1 = a single engine, no router.  Each replica holds
    # its own KV pool and jit caches (len(prefill_buckets)+2 programs
    # per replica — the budget is per engine, not per process).
    replicas: int = 1

    def resolved_buckets(self) -> tuple[int, ...]:
        """Prefill buckets, ascending.  Auto mode: powers of two in
        [8, max_seq_len]."""
        if self.prefill_buckets is not None:
            return tuple(sorted(self.prefill_buckets))
        buckets, b = [], 8
        while b < self.max_seq_len:
            buckets.append(b)
            b *= 2
        buckets.append(self.max_seq_len)
        return tuple(buckets)

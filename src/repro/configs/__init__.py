"""Config registry: ``get_config(name, reduced=False)`` + per-arch shape
applicability for the dry-run matrix."""

from __future__ import annotations

from repro.configs import physics
from repro.configs.base import (  # noqa: F401
    SHAPES,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ParallelismConfig,
    ServeConfig,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
)

_ARCH_MODULES = {
    "minicpm3-4b": "minicpm3_4b",
    "minicpm-2b": "minicpm_2b",
    "granite-8b": "granite_8b",
    "starcoder2-7b": "starcoder2_7b",
    "dbrx-132b": "dbrx_132b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "zamba2-1.2b": "zamba2_1_2b",
    "mamba2-130m": "mamba2_130m",
    "internvl2-1b": "internvl2_1b",
    "hubert-xlarge": "hubert_xlarge",
}

ARCH_NAMES = list(_ARCH_MODULES)

_PHYSICS = {
    "engine_anomaly": physics.engine_anomaly,
    "btagging": physics.btagging,
    "gw": physics.gw,
}

PHYSICS_NAMES = list(_PHYSICS)


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name in _PHYSICS:
        return _PHYSICS[name]()
    if name not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {name!r}; available: {ARCH_NAMES + PHYSICS_NAMES}"
        )
    import dataclasses
    import importlib

    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    if reduced:
        # reduced smoke configs run on CPU in f32
        return dataclasses.replace(mod.reduced_config(), dtype="float32")
    return mod.config()


# ---------------------------------------------------------------------------
# Dry-run cell applicability (DESIGN.md §Arch-applicability)
# ---------------------------------------------------------------------------

# archs whose decode cost per token is sub-quadratic in context length:
# SSM (O(1) state), hybrid (SSM + O(L) shared-attn reads), sliding-window
# (O(window) rolling buffer).
_LONG_CONTEXT_OK = {"mamba2-130m", "zamba2-1.2b", "starcoder2-7b"}
_ENCODER_ONLY = {"hubert-xlarge"}


def cell_status(arch: str, shape_name: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for one (arch x shape) cell."""
    shape = SHAPES[shape_name]
    if arch in _ENCODER_ONLY and shape.kind == "decode":
        return False, "encoder-only: no decode step"
    if shape_name == "long_500k" and arch not in _LONG_CONTEXT_OK:
        return False, "pure full attention: 512k decode needs sub-quadratic attention"
    return True, ""


def dryrun_cells() -> list[tuple[str, str, bool, str]]:
    """All 40 (arch x shape) cells with runnability + skip reason."""
    out = []
    for arch in ARCH_NAMES:
        for shape_name in SHAPES:
            ok, reason = cell_status(arch, shape_name)
            out.append((arch, shape_name, ok, reason))
    return out

"""dbrx-132b [moe] — 40L d6144 48H (GQA kv=8) expert d_ff=10752
vocab=100352, MoE 16 experts top-4 (fine-grained).

hf:databricks/dbrx-base (config marked unverified in the assignment —
dimensions taken exactly from the assignment line).
"""

import dataclasses

from repro.configs.base import MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab_size=100352,
        attn_kind="gqa",
        norm_kind="layernorm",
        act="silu",
        gated_mlp=True,
        rope_theta=500_000.0,
        moe=MoEConfig(n_experts=16, top_k=4, d_expert=10752),
        tie_embeddings=False,
        serve_policy="int8_serve",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(),
        name="dbrx-132b-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=128,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=96),
    )

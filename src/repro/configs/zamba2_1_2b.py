"""zamba2-1.2b [hybrid] — 38L d2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64.

arXiv:2411.15242 — Mamba2 backbone + a weight-shared full transformer block
(attention + MLP over concat(x, x_embed), width 2*d) applied every
``attn_every`` layers, each application with its own KV cache.
Sub-quadratic decode -> runs the ``long_500k`` cell.
"""

import dataclasses

from repro.configs.base import HybridConfig, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        attn_kind="gqa",  # used by the shared block
        norm_kind="rmsnorm",
        act="gelu",
        gated_mlp=True,
        rope_theta=10000.0,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_size=64),
        hybrid=HybridConfig(attn_every=6, concat_residual=True),
        tie_embeddings=True,
        serve_policy="int8_serve",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(),
        name="zamba2-1.2b-reduced",
        n_layers=4,
        d_model=32,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab_size=128,
        ssm=SSMConfig(state_dim=8, head_dim=8, expand=2, chunk_size=16),
        hybrid=HybridConfig(attn_every=2, concat_residual=True),
    )

"""internvl2-1b [vlm] — 24L d896 14H (GQA kv=2) d_ff=4864 vocab=151655.

arXiv:2404.16821 — InternViT-300M + Qwen2-0.5B LM backbone.  Per the
assignment, the modality frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (frontend_dim=1024, 256 tokens) which the
``frontend_proj`` projector maps into the LM embedding space.
"""

import dataclasses

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        attn_kind="gqa",
        norm_kind="rmsnorm",
        act="silu",
        gated_mlp=True,
        rope_theta=1_000_000.0,
        attn_bias=True,  # qwen2 uses qkv bias
        frontend="patch",
        frontend_dim=1024,
        n_frontend_tokens=256,
        tie_embeddings=True,
        serve_policy="int8_serve",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(),
        name="internvl2-1b-reduced",
        n_layers=2,
        d_model=56,
        n_heads=4,
        n_kv_heads=2,
        d_ff=112,
        vocab_size=128,
        frontend_dim=32,
        n_frontend_tokens=4,
    )

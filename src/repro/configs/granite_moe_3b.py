"""granite-moe-3b-a800m [moe] — 32L d1536 24H (GQA kv=8) expert d_ff=512
vocab=49155, MoE 40 experts top-8.

hf:ibm-granite/granite-3.0-*-base family.  NOTE: the assignment line says
"MoE 40e top-8" while its bracket note says "32 experts top-8"; we follow
the spec line (40 experts, top-8) and record the discrepancy here.
"""

import dataclasses

from repro.configs.base import MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        attn_kind="gqa",
        norm_kind="rmsnorm",
        act="silu",
        gated_mlp=True,
        rope_theta=10000.0,
        moe=MoEConfig(n_experts=40, top_k=8, d_expert=512),
        tie_embeddings=True,
        serve_policy="int8_serve",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(),
        name="granite-moe-3b-a800m-reduced",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=32,
        vocab_size=128,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=32),
    )

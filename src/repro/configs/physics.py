"""The paper's three physics models (Table I).

| Parameter        | Engine | B-tagging | GW  |
| Seq. Length      | 50     | 15        | 100 |
| Input Vec. Size  | 1      | 6         | 2   |
| Transf. Blocks   | 3      | 3         | 2   |
| Hidden Vec. Size | 16     | 64        | 32  |
| Output Vec. Size | 2      | 3         | 1   |

Head count is not specified in the paper; we use head_dim=8 (h = d/8).
The engine model "forgoes the normalization layer" (Sec. V-A); the GW model
uses layer normalization (Sec. V-C).  All are encoders with residual
connections, mean pooling and two dense head layers.
"""

from repro.configs.base import ModelConfig


def _physics(name, seq, in_vec, blocks, d, n_classes, norm) -> ModelConfig:
    return ModelConfig(
        name=name,
        family="dense",
        n_layers=blocks,
        d_model=d,
        n_heads=d // 8,
        n_kv_heads=d // 8,
        d_ff=2 * d,
        vocab_size=0,
        attn_kind="gqa",
        norm_kind=norm,
        act="relu",
        gated_mlp=False,
        mlp_bias=True,
        attn_bias=True,
        use_rope=False,  # learned positional embedding instead
        is_encoder=True,
        input_vec_size=in_vec,
        seq_len=seq,
        n_classes=n_classes,
        pool="mean",
        dtype="float32",
        serve_policy="paper_vu13p",
    )


def engine_anomaly() -> ModelConfig:
    return _physics("engine_anomaly", 50, 1, 3, 16, 2, "none")


def btagging() -> ModelConfig:
    return _physics("btagging", 15, 6, 3, 64, 3, "layernorm")


def gw() -> ModelConfig:
    return _physics("gw", 100, 2, 2, 32, 1, "layernorm")

"""starcoder2-7b [dense] — 32L d4608 36H (GQA kv=4) d_ff=18432 vocab=49152.

arXiv:2402.19173 — GQA + RoPE + sliding-window attention (4096), LayerNorm,
non-gated GELU MLP, biases on attn/mlp.  The sliding window gives this arch
a rolling-buffer KV cache and makes ``long_500k`` decodable (O(window) per
token) — see DESIGN.md §Arch-applicability.
"""

import dataclasses

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        d_ff=18432,
        vocab_size=49152,
        attn_kind="gqa",
        norm_kind="layernorm",
        act="gelu",
        gated_mlp=False,
        rope_theta=1_000_000.0,
        sliding_window=4096,
        attn_bias=True,
        mlp_bias=True,
        tie_embeddings=False,
        serve_policy="int8_serve",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(),
        name="starcoder2-7b-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=128,
        sliding_window=8,
    )

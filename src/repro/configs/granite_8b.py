"""granite-8b [dense] — 36L d4096 32H (GQA kv=8) d_ff=14336 vocab=49152.

arXiv:2405.04324 (Granite Code Models) — llama-arch code model.
"""

import dataclasses

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=49152,
        attn_kind="gqa",
        norm_kind="rmsnorm",
        act="silu",
        gated_mlp=True,
        rope_theta=10000.0,
        tie_embeddings=True,
        serve_policy="int8_serve",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(),
        name="granite-8b-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=128,
    )

"""hubert-xlarge [audio] — 48L d1280 16H (kv=16) d_ff=5120 vocab=504.

arXiv:2106.07447 — encoder-only (same arch as wav2vec2).  Per the
assignment the conv feature extractor is a STUB: ``input_specs()`` provides
precomputed frame embeddings (frontend_dim=512).  Training objective is
HuBERT-style masked-unit prediction over 504 cluster units.  Encoder-only:
no decode step -> ``decode_32k``/``long_500k`` cells are skipped.

Deviation note: HuBERT uses a convolutional relative positional embedding;
the stub frontend omits it and we use RoPE as the positional stand-in.
"""

import dataclasses

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        attn_kind="gqa",
        norm_kind="layernorm",
        act="gelu",
        gated_mlp=False,
        attn_bias=True,
        mlp_bias=True,
        frontend="audio",
        frontend_dim=512,
        is_encoder=True,
        tie_embeddings=False,
        serve_policy="int8_serve",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(),
        name="hubert-xlarge-reduced",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=32,
        frontend_dim=16,
    )

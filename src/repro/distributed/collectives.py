"""Distributed-optimization collectives (shard_map-based).

1. **Compressed gradient all-reduce with error feedback** — int8-quantized
   psum (1-bit-Adam/PowerSGD-family trick adapted to int8): each step
   quantizes (grad + error_buffer) to int8 per-block scales, all-reduces
   the codes in int32, dequantizes, and keeps the quantization residual in
   the error buffer.  4x gradient-traffic reduction with provably bounded
   bias (error feedback makes the compression asymptotically unbiased).

2. **Ring collective matmul** — overlaps an all-gather with matmul compute
   via ``jax.lax.ppermute`` (the classic TPU "collective matmul" /
   Wang et al. overlap pattern): each step multiplies the resident shard
   while the next shard is in flight, hiding ICI latency behind the MXU.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

PyTree = Any


# ---------------------------------------------------------------------------
# int8 compressed all-reduce with error feedback
# ---------------------------------------------------------------------------


def _quantize_block(x: jax.Array, bits: int = 8):
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
    codes = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return codes, scale


def compressed_psum_leaf(
    x: jax.Array, axis_name: str | tuple[str, ...], error: jax.Array
):
    """One leaf of the compressed all-reduce.  Returns (mean, new_error).

    Runs INSIDE shard_map: ``x`` is the local gradient shard to be averaged
    over ``axis_name``.
    """
    corrected = x.astype(jnp.float32) + error
    codes, scale = _quantize_block(corrected)
    deq = codes.astype(jnp.float32) * scale
    new_error = corrected - deq  # residual kept locally (error feedback)
    # all-reduce the int codes (widened) and the scales
    summed = jax.lax.psum(codes.astype(jnp.int32).astype(jnp.float32) * scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (summed / n).astype(x.dtype), new_error


def make_compressed_grad_allreduce(mesh: Mesh, axis_name="data"):
    """Returns ``f(grads, errors) -> (mean_grads, new_errors)`` where grads
    are replicated-per-data-shard gradient pytrees (DP averaging)."""

    def _fn(grads: PyTree, errors: PyTree):
        def leaf(g, e):
            return compressed_psum_leaf(g, axis_name, e)

        pairs = jax.tree.map(leaf, grads, errors)
        mean = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        errs = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return mean, errs

    spec = P()  # weights replicated across 'data' in the pure-DP demo path

    def _shardmapped(grads, errors):
        flat, treedef = jax.tree.flatten(grads)
        eflat, _ = jax.tree.flatten(errors)
        outs = []
        f = shard_map(
            lambda g, e: compressed_psum_leaf(g, axis_name, e),
            mesh=mesh,
            in_specs=(spec, spec),
            out_specs=(spec, spec),
        )
        for g, e in zip(flat, eflat):
            outs.append(f(g, e))
        mean = jax.tree.unflatten(treedef, [o[0] for o in outs])
        errs = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return mean, errs

    return _shardmapped


def init_error_buffers(grads_abstract: PyTree) -> PyTree:
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_abstract
    )


# ---------------------------------------------------------------------------
# Ring collective matmul (all-gather overlap via ppermute)
# ---------------------------------------------------------------------------


def ring_collective_matmul(
    mesh: Mesh,
    x: jax.Array,  # (m, k) sharded over rows on `axis`
    w: jax.Array,  # (k, n) sharded over rows (k) on `axis`
    axis: str = "model",
):
    """Computes x @ w where w's contraction dim is sharded, overlapping the
    shard exchange (ppermute ring) with per-shard matmuls.

    Equivalent to ``x @ all_gather(w)`` but the gather is software-pipelined
    against compute — the paper's FIFO producer/consumer overlap at the
    cross-chip level.
    """
    n_shards = mesh.shape[axis]

    def body(x_local, w_local):
        # x_local: (m, k) full columns; w_local: (k/n_shards, n)
        idx = jax.lax.axis_index(axis)
        chunk = w_local.shape[0]

        def step(i, carry):
            acc, w_cur = carry
            # which global k-chunk does w_cur correspond to?
            src = (idx + i) % n_shards
            xs = jax.lax.dynamic_slice_in_dim(x_local, src * chunk, chunk, 1)
            acc = acc + xs @ w_cur
            # rotate shards around the ring (overlaps with next matmul)
            w_nxt = jax.lax.ppermute(
                w_cur, axis,
                [(j, (j - 1) % n_shards) for j in range(n_shards)],
            )
            return acc, w_nxt

        acc = jnp.zeros((x_local.shape[0], w_local.shape[1]), x_local.dtype)
        # the carry becomes device-varying over `axis` inside the loop;
        # older JAX lines have no varying-type system (and no lax.pcast) —
        # there the unannotated carry is already fine under check_rep=False
        pcast = getattr(jax.lax, "pcast", None)
        if pcast is not None:
            acc = pcast(acc, (axis,), to="varying")
        acc, _ = jax.lax.fori_loop(0, n_shards, step, (acc, w_local))
        return acc

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(None, None), P(axis, None)),
        out_specs=P(None, None),
        # every device ends with the identical full product; skip the
        # replication check (classic manual-collective pattern)
        check_rep=False,
    )(x, w)

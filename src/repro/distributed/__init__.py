"""Distribution layer: sharding rules (DP/FSDP/TP/EP/SP), compressed
collectives, and compute/communication overlap primitives."""

from repro.distributed.sharding import ShardingRules  # noqa: F401

"""Logical-axis sharding rules (MaxText-style) for DP/FSDP/TP/EP/SP.

Every parameter leaf carries ``logical_axes`` (models/params.py).  Rules
map each logical axis to mesh axes; an axis whose size does not divide the
mesh-axis product falls back to replication (recorded, so the dry-run can
report which tensors lost their preferred sharding — hillclimb material).

Default mapping (single pod (data=16, model=16); 'pod' joins the data axes
on the multi-pod mesh):

  batch       -> (pod, data)        activations / cache batch
  embed       -> data   [FSDP]      weights' non-TP axis (ZeRO-3)
  heads/kv_heads/mlp/q_lora/kv_lora/inner -> model  [TP]
  vocab       -> model  [TP]
  experts     -> model  [EP]
  cache_len   -> None (or model under SP for long-context decode)
  layers      -> None (scan axis)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ParallelismConfig


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    plan: ParallelismConfig = dataclasses.field(default_factory=ParallelismConfig)
    overrides: dict[str, tuple[str, ...] | None] = dataclasses.field(
        default_factory=dict
    )
    # populated as specs are built: leaves that fell back to replication
    fallbacks: list[tuple[str, int]] = dataclasses.field(default_factory=list)

    def _mesh_axes_for(self, logical: str | None):
        if logical is None:
            return None
        if logical in self.overrides:
            return self.overrides[logical]
        names = self.mesh.axis_names
        has_pod = "pod" in names
        batch_axes = ("pod", "data") if has_pod else ("data",)
        m = {
            "batch": batch_axes if self.plan.dp else None,
            "embed": ("data",) if self.plan.fsdp else None,
            "frontend": None,
            "heads": ("model",) if self.plan.tp else None,
            "kv_heads": ("model",) if self.plan.tp else None,
            "mlp": ("model",) if self.plan.tp else None,
            "inner": ("model",) if self.plan.tp else None,
            "q_lora": ("model",) if self.plan.tp else None,
            "kv_lora": ("model",) if self.plan.tp else None,
            "vocab": ("model",) if self.plan.tp else None,
            "experts": ("model",) if self.plan.ep else None,
            "ssm_heads": None,
            "cache_len": ("model",) if self.plan.sp else None,
            "seq": ("model",) if self.plan.sp else None,
            "layers": None,
        }
        return m.get(logical)

    def spec_for(
        self, logical_axes: tuple[str | None, ...], shape: tuple[int, ...]
    ) -> P:
        """PartitionSpec with divisibility fallback per axis."""
        if not logical_axes:
            return P()
        parts = []
        used: set[str] = set()
        for dim, (logical, size) in enumerate(zip(logical_axes, shape)):
            axes = self._mesh_axes_for(logical)
            if not axes:
                parts.append(None)
                continue
            # a mesh axis may be used at most once per spec
            axes = tuple(a for a in axes if a in self.mesh.axis_names and a not in used)
            if not axes:
                parts.append(None)
                continue
            total = int(np.prod([self.mesh.shape[a] for a in axes]))
            if size % total != 0:
                # try a prefix of the axes tuple before giving up
                ok = None
                for cut in range(len(axes) - 1, 0, -1):
                    sub = axes[:cut]
                    t = int(np.prod([self.mesh.shape[a] for a in sub]))
                    if size % t == 0:
                        ok = sub
                        break
                if ok is None:
                    self.fallbacks.append((str(logical), size))
                    parts.append(None)
                    continue
                axes = ok
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        return P(*parts)

    def sharding_for(self, logical_axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, shape))

    def tree_shardings(self, abstract_tree: Any, axes_tree: Any) -> Any:
        """NamedSharding tree for (ShapeDtypeStruct tree, logical-axes tree)."""

        def _one(leaf, axes):
            return self.sharding_for(tuple(axes), leaf.shape)

        return jax.tree.map(
            _one, abstract_tree, axes_tree,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    def batch_spec(
        self,
        ndim: int,
        sharded_dims: dict[int, str] | None = None,
        shape: tuple[int, ...] | None = None,
    ) -> P:
        """Spec for an activation/batch tensor: dim 0 = batch; extra dims
        via {dim: logical} (e.g. {1: 'seq'} for sequence parallelism).
        When ``shape`` is given, axes that don't divide fall back (e.g.
        global_batch=1 decode cells replicate the batch dim)."""
        names = self.mesh.axis_names
        batch_axes = ("pod", "data") if "pod" in names else ("data",)
        parts: list = [batch_axes if len(batch_axes) > 1 else batch_axes[0]]
        parts += [None] * (ndim - 1)
        for dim, logical in (sharded_dims or {}).items():
            axes = self._mesh_axes_for(logical)
            if axes:
                parts[dim] = axes if len(axes) > 1 else axes[0]
        if shape is not None:
            for dim in range(ndim):
                part = parts[dim]
                if part is None:
                    continue
                axes = part if isinstance(part, tuple) else (part,)
                total = int(np.prod([self.mesh.shape[a] for a in axes]))
                while axes and shape[dim] % total != 0:
                    axes = axes[:-1]
                    total = int(
                        np.prod([self.mesh.shape[a] for a in axes])
                    ) if axes else 1
                parts[dim] = (
                    None if not axes else (axes if len(axes) > 1 else axes[0])
                )
        return P(*parts)

    def batch_sharding(self, ndim: int, sharded_dims=None, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.batch_spec(ndim, sharded_dims, shape))


def param_shardings(rules: ShardingRules, cfg, model_module) -> Any:
    """Sharding tree for a model's parameters."""
    from repro.models import params as params_lib

    spec = model_module.param_spec(cfg)
    abstract = params_lib.abstract_params(spec)
    axes = params_lib.logical_axes(spec)
    return rules.tree_shardings(abstract, axes)


def cache_shardings(
    rules: ShardingRules,
    cfg,
    batch: int,
    max_len: int,
    quantized: bool = False,
    layout: str = "dense",
    **layout_kw,
) -> Any:
    """Sharding tree for decode caches (serve/kv_cache.cache_logical_axes).

    ``layout`` selects the KV storage layout: dense slabs shard the batch
    and cache_len axes; paged pools shard over kv_heads (TP) with the page
    axis replicated and the page table sharded over batch.  Extra
    ``layout_kw`` (page_size/num_pages) are forwarded to the spec builder.
    """
    from repro.serve import kv_cache

    abstract = kv_cache.abstract_caches(
        cfg, batch, max_len, quantized=quantized, layout=layout, **layout_kw
    )
    axes_map = kv_cache.cache_logical_axes(
        cfg, quantized=quantized, layout=layout
    )

    def _walk(abs_node, axes_node):
        if isinstance(abs_node, jax.ShapeDtypeStruct):
            return rules.sharding_for(tuple(axes_node), abs_node.shape)
        return {k: _walk(abs_node[k], axes_node[k]) for k in abs_node}

    return _walk(abstract, axes_map)

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> re-analyse
for the three selected cells.  Each iteration is a tagged dry-run compile;
results accumulate in experiments/perf/*.json and are summarized into
EXPERIMENTS.md §Perf by experiments/make_reports.py.

Cells (selection criteria from the assignment):
  A. minicpm3-4b x decode_32k  — most representative of the paper's
     technique (low-latency quantized decode); worst useful-FLOP ratio.
  B. granite-moe-3b-a800m x train_4k — worst roofline fraction (0.006).
  C. internvl2-1b x train_4k   — the only collective-dominant cell.

Run:  PYTHONPATH=src python experiments/perf_hillclimb.py [A|B|C|all]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import dataclasses
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import ParallelismConfig
from repro.launch.dryrun import run_cell

OUT = os.path.join(os.path.dirname(__file__), "perf")


def _show(tag, r):
    if r.get("status") != "ok":
        print(f"  {tag}: {r.get('status')} {r.get('error','')[:200]}")
        return
    t = r["terms_fused"]
    print(
        f"  {tag}: compute {t['compute_s']:.3f}s  memory {t['memory_s']:.3f}s  "
        f"collective {t['collective_s']:.3f}s  dominant={t['dominant']}  "
        f"useful={r['useful_ratio_fused']:.3f}  "
        f"tempGB={r['memory_stats'].get('temp_bytes',0)/2**30:.1f}"
    )


def cell_a():
    print("=== Cell A: minicpm3-4b x decode_32k (paper-representative) ===")
    # A0: paper-faithful baseline (MLA K/V materialized per step, per layer)
    r = run_cell("minicpm3-4b", "decode_32k", "pod", out_dir=OUT, tag="A0_baseline")
    _show("A0 baseline (paper-faithful MLA)", r)
    # A1: absorbed MLA decode — hypothesis: the per-step re-materialization
    # of 32k x 40-head K/V from the latent is ~100x the useful FLOPs and
    # most of the HBM traffic; absorbing wk_b/wv_b into q/out projections
    # attends directly against the latent cache.
    r = run_cell(
        "minicpm3-4b", "decode_32k", "pod", out_dir=OUT, tag="A1_absorb",
        kernel={"mla_absorb": True},
    )
    _show("A1 absorbed-MLA decode", r)
    # A2: absorbed + 32x8 mesh — hypothesis: decode is cache-read bound;
    # batch 128 over data=32 halves the per-device latent cache slice, and
    # 40 heads % 8 == 0 restores TP on the head einsums.
    r = run_cell(
        "minicpm3-4b", "decode_32k", "pod8", out_dir=OUT, tag="A2_absorb_pod8",
        kernel={"mla_absorb": True},
    )
    _show("A2 absorbed + (32 data x 8 model) mesh", r)
    # A3: + LUT softmax decode path (paper's 3-stage softmax in the
    # attention score pipeline; same shape, fused-kernel costing).
    r = run_cell(
        "minicpm3-4b", "decode_32k", "pod8", out_dir=OUT, tag="A3_absorb_lut",
        kernel={"mla_absorb": True, "softmax_mode": "lut"},
    )
    _show("A3 absorbed + LUT softmax", r)
    # A4: int8 latent cache — hypothesis: after A1 the decode step is
    # latent-cache-read bound (128 x 32k x 288 x 2B = 2.4 GB/layer global);
    # per-token int8 quantization (the paper's fixed-point datapath on the
    # cache) halves it -> memory term ~ -45%.
    r = run_cell(
        "minicpm3-4b", "decode_32k", "pod", out_dir=OUT, tag="A4_int8_latent",
        kernel={"mla_absorb": True},
        quantized_cache=True,
    )
    _show("A4 absorbed + int8 latent cache", r)


def cell_b():
    print("=== Cell B: granite-moe-3b-a800m x train_4k (worst roofline) ===")
    r = run_cell("granite-moe-3b-a800m", "train_4k", "pod", out_dir=OUT, tag="B0_baseline")
    _show("B0 baseline (remat=minimal, 16x16, EP fallback: 40%16!=0)", r)
    # B1: remat=full + grad_accum=4 — hypothesis: the f32 saved-dot stacks
    # dominate HBM traffic and temp memory; full remat trades ~25% more
    # FLOPs (tiny: compute term is 0.19s) for a large memory-term cut.
    r = run_cell(
        "granite-moe-3b-a800m", "train_4k", "pod", out_dir=OUT, tag="B1_remat_accum",
        plan=ParallelismConfig(remat="full", grad_accum=4),
    )
    _show("B1 remat=full + grad_accum=4", r)
    # B2: 32x8 mesh — hypothesis: 40 experts % 8 == 0 restores expert
    # parallelism (baseline replicates all 40 experts' dispatch buffers);
    # EP shards the (E, C, d) batches 8-way.
    r = run_cell(
        "granite-moe-3b-a800m", "train_4k", "pod8", out_dir=OUT, tag="B2_pod8_ep",
        plan=ParallelismConfig(remat="full", grad_accum=4),
    )
    _show("B2 + (32 data x 8 model) mesh (EP active)", r)
    # B3: capacity_factor 1.0 — hypothesis: dispatch buffers scale with
    # cf; cf=1.0 drops ~20% of dispatch traffic for a small drop rate.
    r = run_cell(
        "granite-moe-3b-a800m", "train_4k", "pod8", out_dir=OUT, tag="B3_cf1",
        plan=ParallelismConfig(remat="full", grad_accum=4),
        cfg_transform=lambda c: dataclasses.replace(
            c, moe=dataclasses.replace(c.moe, capacity_factor=1.0)
        ),
    )
    _show("B3 + capacity_factor=1.0", r)


def cell_c():
    print("=== Cell C: internvl2-1b x train_4k (collective-bound) ===")
    r = run_cell("internvl2-1b", "train_4k", "pod", out_dir=OUT, tag="C0_baseline")
    _show("C0 baseline", r)
    # C1: TP-safe cross-entropy — hypothesis: take_along_axis over the
    # vocab-sharded logits forces an all-gather of (b, s, 152k) logits;
    # the one-hot einsum form partitions to a local dot + psum.
    r = run_cell(
        "internvl2-1b", "train_4k", "pod", out_dir=OUT, tag="C1_tploss",
        kernel={"tp_loss": True},
    )
    _show("C1 TP-safe cross-entropy", r)
    # C2: + remat=full + grad_accum=4 — memory-term lever as in B1.
    r = run_cell(
        "internvl2-1b", "train_4k", "pod", out_dir=OUT, tag="C2_remat_accum",
        kernel={"tp_loss": True},
        plan=ParallelismConfig(remat="full", grad_accum=4),
    )
    _show("C2 + remat=full + grad_accum=4", r)
    # C3: fsdp off — hypothesis: at 0.9B params the weights fit replicated;
    # dropping FSDP removes the per-layer weight all-gathers, trading HBM
    # capacity (params+opt replicated over 'data') for collective traffic.
    r = run_cell(
        "internvl2-1b", "train_4k", "pod", out_dir=OUT, tag="C3_no_fsdp",
        kernel={"tp_loss": True},
        plan=ParallelismConfig(remat="full", grad_accum=4, fsdp=False),
    )
    _show("C3 + fsdp=False (weights replicated over data)", r)


def cell_extra():
    """Follow-up iterations after inspecting collective breakdowns."""
    print("=== Cell C follow-up ===")
    # C4: attention-TP off — hypothesis: 14 heads % 16 != 0 means the TP
    # shards cut across head boundaries; the (b,s,896)->(b,s,14,64) head
    # split then forces XLA to re-distribute with full-batch f32
    # all-reduces (581 GB/device/step).  Replicating attention weights
    # over 'model' (keeping MLP/vocab TP) removes them.
    r = run_cell(
        "internvl2-1b", "train_4k", "pod", out_dir=OUT, tag="C4_no_attn_tp",
        kernel={"tp_loss": True},
        plan=ParallelismConfig(remat="full", grad_accum=4),
        overrides={"heads": None, "kv_heads": None},
    )
    _show("C4 attention-TP off (head-misaligned)", r)
    # C5: head-ALIGNED TP=2 — hypothesis: C4 killed the misaligned
    # all-reduces but unsharded attention 16x over 'model', raising the
    # memory term; a (128 data x 2 model) mesh keeps TP on attention
    # (14 % 2 == 0) with aligned head splits: both terms should drop.
    r = run_cell(
        "internvl2-1b", "train_4k", "pod2", out_dir=OUT, tag="C5_pod2",
        kernel={"tp_loss": True},
        plan=ParallelismConfig(remat="full", grad_accum=4),
    )
    _show("C5 head-aligned TP=2 (128x2 mesh)", r)
    print("=== Cell B follow-up ===")
    # B4: grad_accum=8 — hypothesis: B3 still holds a 29 GB live set
    # (>16 GB HBM); halving the microbatch fits the chip with ~unchanged
    # roofline terms (traffic per token is constant).
    r = run_cell(
        "granite-moe-3b-a800m", "train_4k", "pod8", out_dir=OUT, tag="B4_accum8",
        plan=ParallelismConfig(remat="full", grad_accum=8),
        cfg_transform=lambda c: dataclasses.replace(
            c, moe=dataclasses.replace(c.moe, capacity_factor=1.0)
        ),
    )
    _show("B4 grad_accum=8 (fit HBM)", r)


def cell_d():
    """Bonus (beyond the required three): the largest cell by absolute
    compute — dbrx-132b train_4k."""
    print("=== Cell D (bonus): dbrx-132b x train_4k ===")
    r = run_cell("dbrx-132b", "train_4k", "pod", out_dir=OUT, tag="D0_baseline")
    _show("D0 baseline", r)
    # D1: remat=full + grad_accum=8 — the activation live set at 132B
    # params / 1M tokens is far beyond HBM (353.8 GiB temp at baseline);
    # same lever as B1/C2.
    r = run_cell(
        "dbrx-132b", "train_4k", "pod", out_dir=OUT, tag="D1_remat_accum8",
        kernel={"tp_loss": True},
        plan=ParallelismConfig(remat="full", grad_accum=8),
    )
    _show("D1 remat=full + grad_accum=8 + tp_loss", r)
    # D2: capacity_factor=1.0 (16 experts % 16 == 0, EP already active)
    r = run_cell(
        "dbrx-132b", "train_4k", "pod", out_dir=OUT, tag="D2_cf1",
        kernel={"tp_loss": True},
        plan=ParallelismConfig(remat="full", grad_accum=8),
        cfg_transform=lambda c: dataclasses.replace(
            c, moe=dataclasses.replace(c.moe, capacity_factor=1.0)
        ),
    )
    _show("D2 + capacity_factor=1.0", r)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    os.makedirs(OUT, exist_ok=True)
    if which in ("A", "all"):
        cell_a()
    if which in ("B", "all"):
        cell_b()
    if which in ("C", "all"):
        cell_c()
    if which in ("extra", "all"):
        cell_extra()
    if which in ("D",):
        cell_d()

"""Generates EXPERIMENTS.md from the dry-run cache, the perf-hillclimb
results, and (if present) the fidelity benchmark CSV.

    PYTHONPATH=src python experiments/make_reports.py
"""

import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)  # for the benchmarks package

DRYRUN = os.path.join(ROOT, "experiments", "dryrun")
PERF = os.path.join(ROOT, "experiments", "perf")
AUC_CSV = os.path.join(ROOT, "experiments", "auc_vs_bits.csv")


def load(pattern):
    out = {}
    for f in sorted(glob.glob(pattern)):
        with open(f) as fh:
            out[os.path.basename(f)[:-5]] = json.load(fh)
    return out


def fmt_bytes(b):
    return f"{b/2**30:.1f} GiB"


def dryrun_section():
    cells = load(os.path.join(DRYRUN, "*.json"))
    ok = [d for d in cells.values() if d.get("status") == "ok"]
    skip = [d for d in cells.values() if d.get("status") == "skip"]
    err = [d for d in cells.values() if d.get("status") == "error"]
    lines = [
        "## §Dry-run",
        "",
        f"Every (architecture × input-shape × mesh) cell was lowered and "
        f"compiled with `jax.jit(...).lower().compile()` on 512 forced host "
        f"devices: **{len(ok)} compiles OK, {len(skip)} documented skips, "
        f"{len(err)} errors** "
        f"(meshes: single-pod 16×16 = 256 chips, multi-pod 2×16×16 = 512 "
        f"chips over the `pod` axis).",
        "",
        "Skips (per DESIGN.md §Arch-applicability): encoder-only archs have "
        "no decode step; `long_500k` requires sub-quadratic attention and "
        "runs only for mamba2 (O(1) state), zamba2 (SSM + shared-attn) and "
        "starcoder2 (O(window) rolling KV).",
        "",
        "Compile wall times: 1.4–60 s per cell on the CPU host.  Per-cell "
        "JSON (memory analysis, per-op collective bytes, trip counts, "
        "sharding fallbacks) is cached under `experiments/dryrun/`.",
        "",
        "| arch | shape | mesh | per-device memory (args+temp) | collective schedule (per-device bytes/step) |",
        "|---|---|---|---|---|",
    ]
    for d in ok:
        if d["mesh"] not in ("pod", "multipod"):
            continue
        ms = d.get("memory_stats", {})
        tot = ms.get("argument_bytes", 0) + ms.get("temp_bytes", 0)
        colls = ", ".join(
            f"{k.replace('all-','a')}:{v/2**30:.1f}G"
            for k, v in sorted(d.get("coll_bytes", {}).items())
            if v > 1e8
        ) or "—"
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {fmt_bytes(tot)} | {colls} |"
        )
    lines.append("")
    return "\n".join(lines)


def roofline_section():
    from benchmarks.roofline_table import markdown

    lines = [
        "## §Roofline",
        "",
        "Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 4 × 50 GB/s "
        "ICI links per chip.  All terms are **seconds per step, per "
        "device**, from the trip-count-aware HLO parser "
        "(`repro/roofline/hlo_parser.py`).  `compiled.cost_analysis()` "
        "visits scan bodies once and under-counts scan-over-layers models "
        "by ~n_layers× (verified; tests/test_hlo_parser.py) — the parser "
        "multiplies while bodies by trip counts recovered from loop-"
        "condition constants.",
        "",
        "Two variants per cell: **baseline** = the module exactly as XLA "
        "lowered it (attention volume in HBM); **fused** = the `attnvol`-"
        "tagged volume re-priced as the fused streaming Pallas kernel "
        "(causal/window-aware FLOPs; q/k/v/out + cache-read traffic only) — "
        "the paper's stage-2+3 fusion applied at scale.  `6ND/HLO` is the "
        "MODEL_FLOPS/HLO_FLOPs useful-compute ratio; `RL frac` = fused "
        "compute term / dominant term (1.0 = compute-bound at peak).",
        "",
        "### Single-pod (16×16, 256 chips)",
        "",
        markdown(DRYRUN, mesh="pod"),
        "",
        "### Multi-pod delta (2×16×16, 512 chips)",
        "",
        "The multi-pod mesh joins the `pod` axis to the data axes (batch "
        "and FSDP sharding over 32-way data); compiles prove the pod-axis "
        "sharding (collectives cross the DCN boundary).  Full rows in "
        "`experiments/dryrun/*multipod.json`.",
        "",
        "Per-cell one-line reading (fused variant, pod mesh): every cell "
        "is memory- or collective-dominant at baseline — the iteration "
        "log in §Perf drives the dominant terms down for the three "
        "selected cells.",
        "",
    ]
    return "\n".join(lines)


def _perf_row(tag):
    files = glob.glob(os.path.join(PERF, f"*__{tag}.json"))
    if not files:
        return None
    with open(files[0]) as f:
        return json.load(f)


def perf_section():
    lines = ["## §Perf", ""]
    lines += [
        "Methodology: per §Roofline the three terms identify the "
        "bottleneck; each iteration states a hypothesis with napkin math, "
        "re-lowers, re-analyses, and records confirmed/refuted.  The "
        "paper-faithful baseline and the optimized variant are reported "
        "separately.  Stop rule: three consecutive <5% changes on the "
        "dominant term.",
        "",
        "### Pre-iteration fixes surfaced by the first compiles (apply to ALL cells)",
        "",
        "| fix | before → after (granite-8b train_4k, memory term) |",
        "|---|---|",
        "| activation sharding constraints at block boundaries (XLA had replicated the batch to resolve the FSDP/DP conflict; observed full-batch f32 buffers in the bwd scan) | 156.6 s → 14.2 s |",
        "| bf16 params + 4D attention path (no batch×head flatten → no involuntary SPMD remat) | 1080 s → 156.6 s |",
        "| fused streaming attention (the paper's stage-2+3, costed as the Pallas kernel) | 14.2 s → 11.6 s |",
        "| remat=full (drops XLA's f32 saved-dot stacks; +22% compute) | 11.6 s → 9.2 s |",
        "",
    ]

    cells = [
        (
            "Cell A — minicpm3-4b × decode_32k (most representative of the "
            "paper's technique: low-latency quantized decode)",
            [
                ("A0_baseline",
                 "paper-faithful MLA decode: K/V re-materialized from the "
                 "latent for all 32k positions per step per layer (the "
                 "FPGA streams full K/V the same way)"),
                ("A1_absorb",
                 "HYPOTHESIS: that re-materialization is ~160× the useful "
                 "FLOPs (2·N·B ≈ 1e12 global vs HLO 1.6e14) and most of "
                 "the traffic → absorb wk_b/wv_b into the query/output "
                 "projections, attend directly against the latent cache. "
                 "CONFIRMED: compute 136×↓, memory −42%, useful 0.006→1.0"),
                ("A2_absorb_pod8",
                 "HYPOTHESIS: (32 data × 8 model) halves the per-device "
                 "batch slice of the cache and restores head-TP (40%8=0). "
                 "REFUTED: per-device cache slice is B·L/chips for any "
                 "mesh aspect — memory unchanged (+4%), collectives up; "
                 "keep the 16×16 mesh"),
                ("A3_absorb_lut",
                 "paper's 3-stage LUT softmax in the decode score path: "
                 "roofline-neutral (decode attention is cache-read bound; "
                 "the LUT trades VPU transcendentals for MXU one-hot reads "
                 "— a fidelity/efficiency feature, not a bandwidth one). "
                 "CONFIRMED-NEUTRAL"),
                ("A4_int8_latent",
                 "HYPOTHESIS: post-A1 the step is latent-cache-read bound "
                 "(128·32k·288·2B ≈ 2.4 GB/layer global); per-token int8 "
                 "on the latent (the paper's fixed-point datapath applied "
                 "to the cache) halves it. CONFIRMED beyond prediction: "
                 "memory 0.110→0.035 s (int8 also removes the bf16→f32 "
                 "expansion copies); decode logits within 5e-3 of fp "
                 "(tests/test_serving.py)"),
            ],
            "A0 → A4: dominant memory term 0.189 s → 0.035 s (5.4×), "
            "useful-FLOP ratio 0.006 → 1.00.  The full paper datapath — "
            "absorbed latent attention + int8 cache + LUT softmax — is "
            "the optimized variant; the paper-faithful baseline is kept "
            "as A0.  Final: ≈0.27 ms/token amortized over 128 streams.",
        ),
        (
            "Cell B — granite-moe-3b-a800m × train_4k (worst roofline "
            "fraction of the 32-cell baseline: 0.006)",
            [
                ("B0_baseline",
                 "16×16 mesh; 40 experts % 16 ≠ 0 → EP silently fell back "
                 "to replication (recorded by the sharding rules)"),
                ("B1_remat_accum",
                 "HYPOTHESIS: f32 saved-dot stacks (143.6 GiB temp!) "
                 "dominate; remat=full + grad_accum=4 cuts the live set "
                 "4×. PARTIALLY CONFIRMED: temp 143.6→33.4 GiB but memory "
                 "term only −1.3% — traffic per token was already flat; "
                 "the win is fitting HBM, not bandwidth"),
                ("B2_pod8_ep",
                 "HYPOTHESIS: (32 data × 8 model): 40 % 8 = 0 activates "
                 "expert parallelism, sharding the (E,C,d) dispatch "
                 "buffers 8-way. CONFIRMED: memory −20%, collective −17%"),
                ("B3_cf1",
                 "HYPOTHESIS: dispatch traffic ∝ capacity_factor; cf "
                 "1.25→1.0 cuts ~20% of dispatch bytes for a ~2% drop "
                 "rate. CONFIRMED: memory −3.8%, useful 0.70→0.80"),
                ("B4_accum8",
                 "grad_accum=8 to fit the 16 GiB HBM (29.2→14.7 GiB); "
                 "memory +2% (<5% stop threshold reached)"),
            ],
            "B0 → B4: dominant memory term 32.1 s → 25.1 s (−22%), temp "
            "143.6 → 14.7 GiB (now fits v5e HBM).  Remaining bound is "
            "architectural: d_expert=512 experts give this MoE an "
            "arithmetic intensity of ~170 FLOPs/byte of expert I/O — "
            "identified next step (out of scope of sharding): MegaBlocks-"
            "style per-shard local dispatch to remove the global scatter "
            "all-reduce (1.2 TB/device/step observed).",
        ),
        (
            "Cell C — internvl2-1b × train_4k (the only collective-"
            "dominant baseline cell)",
            [
                ("C0_baseline", "16×16 mesh: collective 3.02 s > memory 2.91 s"),
                ("C1_tploss",
                 "HYPOTHESIS: take_along_axis over vocab-sharded logits "
                 "all-gathers (b,s,152k) → switch to one-hot einsum. "
                 "REFUTED: collective bytes unchanged — XLA had already "
                 "partitioned the gather; kept (it is still the safe "
                 "form) but not the bottleneck"),
                ("C2_remat_accum",
                 "remat=full + grad_accum=4: memory −9%, temp 43.3→7.8 GiB "
                 "(fits HBM); collective unchanged — confirms the "
                 "bottleneck is not weight gathers"),
                ("C3_no_fsdp",
                 "HYPOTHESIS: FSDP weight all-gathers dominate → replicate "
                 "weights. REFUTED: collective unchanged (581 GB/device "
                 "all-reduce remains) — so the traffic is activation-side"),
                ("C4_no_attn_tp",
                 "HYPOTHESIS (from the all-reduce breakdown): 14 heads % "
                 "16 ≠ 0 — TP shards cut across head boundaries, and the "
                 "(b,s,896)→(b,s,14,64) head split forces full-batch f32 "
                 "redistribution all-reduces. Turn attention TP off. "
                 "CONFIRMED: collective 3.06→0.11 s (27×) — but memory "
                 "rose to 3.67 s (attention now replicated over model): "
                 "net bound WORSE (3.06→3.67)"),
                ("C5_pod2",
                 "HYPOTHESIS: head-ALIGNED TP=2 on a (128 data × 2 model) "
                 "mesh keeps attention sharded (14%2=0) without the "
                 "misaligned redistribution. CONFIRMED: memory 1.42 s, "
                 "collective 0.12 s"),
            ],
            "C0 → C5: step bound 3.02 s → 1.42 s (2.1×), dominant "
            "collective → memory, temp fits HBM (6.6 GiB).  Lesson "
            "recorded in DESIGN.md: TP degree must divide the HEAD count, "
            "not merely the merged head×dim — the sharding rules now "
            "surface this as a fallback warning.",
        ),
    ]

    cells.append(
        (
            "Cell D (bonus, beyond the required three) — dbrx-132b × "
            "train_4k (largest absolute compute)",
            [
                ("D0_baseline", "16×16 mesh, remat=minimal"),
                ("D1_remat_accum8",
                 "remat=full + grad_accum=8 + tp-safe loss: live set "
                 "353.8→40.9 GiB (8.6×); roofline terms ~flat as expected "
                 "(traffic per token constant)"),
                ("D2_cf1",
                 "capacity_factor 1.25→1.0: memory −7%, compute −18% "
                 "(dispatch + expert GEMMs shrink ∝ cf), useful 0.63→0.77"),
            ],
            "D0 → D2: memory 108.2 s → 99.0 s; the 132B cell needs "
            "grad_accum≈32 plus weight-streaming or a third mesh axis "
            "(pipeline stages) to reach the 16 GiB envelope — recorded as "
            "the identified next step for the largest arch.",
        )
    )

    for title, iters, summary in cells:
        lines.append(f"### {title}")
        lines.append("")
        lines.append(
            "| iter | change / hypothesis | compute s | memory s | "
            "collective s | dominant | 6ND/HLO | temp GiB |"
        )
        lines.append("|---|---|---|---|---|---|---|---|")
        for tag, desc in iters:
            d = _perf_row(tag)
            if d is None or d.get("status") != "ok":
                lines.append(f"| {tag} | {desc} | – | – | – | – | – | – |")
                continue
            t = d["terms_fused"]
            lines.append(
                f"| {tag} | {desc} | {t['compute_s']:.3f} | "
                f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
                f"{t['dominant']} | {d['useful_ratio_fused']:.3f} | "
                f"{d['memory_stats'].get('temp_bytes', 0)/2**30:.1f} |"
            )
        lines.append("")
        lines.append(f"**Outcome.** {summary}")
        lines.append("")
    return "\n".join(lines)


def fidelity_section():
    lines = [
        "## §Fidelity (paper Figs. 9–11)",
        "",
        "AUC ratio (quantized vs float model) vs fractional bits at 6 "
        "integer bits, PTQ vs QAT, on the three physics models trained on "
        "the synthetic physics generators (`repro/data/physics.py`).  The "
        "paper's protocol: the metric compares quantized outputs to the "
        "FLOAT model's outputs, not ground truth.",
        "",
    ]
    if os.path.exists(AUC_CSV):
        with open(AUC_CSV) as f:
            rows = [r.strip() for r in f if r.startswith("auc_vs_bits,")]
        lines.append("| model | mode | frac bits | AUC float | AUC quant | ratio |")
        lines.append("|---|---|---|---|---|---|")
        for r in rows:
            _, model, mode, _, fb, af, aq, ratio = r.split(",")
            if int(fb) in (1, 2, 4, 6, 8, 10):
                lines.append(
                    f"| {model} | {mode} | {fb} | {af} | {aq} | {ratio} |"
                )
        lines.append("")
        lines.append(
            "Matches the paper's shape: ratios collapse below ~4 "
            "fractional bits and saturate near 1.0 by ~6 bits (the "
            "paper's chosen operating points: engine 6, b-tag 10 PTQ / 6 "
            "QAT, GW 6).  The paper's central QAT-vs-PTQ claim reproduces "
            "at the aggressive end: at 1 fractional bit the engine model "
            "keeps a 0.79 AUC ratio under QAT vs 0.31 under PTQ."
        )
    else:
        lines.append(
            "(run `PYTHONPATH=src python -m benchmarks.run auc_vs_bits "
            "> experiments/auc_vs_bits.csv` to populate)"
        )
    lines.append("")
    return "\n".join(lines)


def latency_section():
    from benchmarks.latency_tables import run as lat_run

    lines = [
        "## §Latency-tables (paper Tables II–IV)",
        "",
        "```",
        *lat_run(),
        "```",
        "",
        "The FPGA-style cycle model preserves the paper's monotone "
        "R-trends; the TPU columns document the hardware-adaptation "
        "finding that for <10k-param models the whole contraction fits "
        "one 128-lane MXU pass, so R degenerates (passes=1) and the paper-"
        "scale models are HBM-streaming-bound at ~0.02–0.25 µs/inference "
        "roofline.  R becomes meaningful again at LM-scale GEMMs (see the "
        "resources benchmark).",
        "",
    ]
    return "\n".join(lines)


def main():
    doc = "\n".join(
        [
            "# EXPERIMENTS",
            "",
            "Paper: *Low Latency Transformer Inference on FPGAs for "
            "Physics Applications with hls4ml* (2024).  See DESIGN.md for "
            "the TPU adaptation map; README.md for how to run everything "
            "here.",
            "",
            dryrun_section(),
            roofline_section(),
            perf_section(),
            fidelity_section(),
            latency_section(),
        ]
    )
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write(doc)
    print(f"wrote {out} ({len(doc.splitlines())} lines)")


if __name__ == "__main__":
    main()
